"""Blockwise online-softmax (flash) attention as a Pallas TPU kernel.

Design (TPU-native, not a CUDA port):
  * Grid (B, H, nq, nk) — the trailing kv dimension is "arbitrary"
    (sequential), so the online-softmax running state (m, l, acc) lives in
    VMEM scratch and carries across kv blocks; q/head/batch dims are
    parallel.
  * BlockSpec tiles: q (1, qb, 1, hd), k/v (1, kb, 1, hd) — VMEM working
    set is O(qb*hd + kb*hd + qb*kb); qb=kb=128 aligns scores (qb x kb) and
    the (qb x hd) matmuls with the 128x128 MXU.
  * GQA without repeat: the kv BlockSpec index map sends query head h to
    kv head h // G, so KV tiles are fetched once per group — the HBM
    traffic win that matters at decode/prefill.
  * Causal + sliding-window masking is done with block-level early-exit
    (whole kv blocks that cannot intersect the mask are skipped before
    any compute) plus an elementwise mask inside boundary blocks.

Validated in interpret mode against repro.kernels.ref.attention_ref over
shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: int, scale: float, kv_len: int,
                 q_offset: int, q_block: int, kv_block: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this tile
    q_pos = qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0) + q_offset
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)

    # block-level visibility: skip tiles fully outside the mask
    blk_q_last = qi * q_block + q_block - 1 + q_offset
    blk_q_first = qi * q_block + q_offset
    blk_k_first = ki * kv_block
    blk_k_last = ki * kv_block + kv_block - 1
    visible = blk_k_first <= blk_q_last if causal else True
    if causal and window > 0:
        visible = jnp.logical_and(visible,
                                  blk_k_last > blk_q_first - window)

    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (qb, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (kb, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
            if window > 0:
                mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # (qb, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    if isinstance(visible, bool):
        compute()
    else:
        pl.when(visible)(compute)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)    # fully-masked rows -> zeros
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, T, K, hd), H % K == 0.

    Causal convention matches ref.attention_ref: query i sits at absolute
    position i + (T - S) in the key space (supports appended-query
    layouts). Returns (B, S, H, hd) in q.dtype.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    qb = min(q_block, S)
    kb = min(kv_block, T)
    q_pad = (-S) % qb
    k_pad = (-T) % kb
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    Sp, Tp = S + q_pad, T + k_pad
    nq, nk = Sp // qb, Tp // kb

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window,
        scale=1.0 / math.sqrt(hd), kv_len=T, q_offset=T - S,
        q_block=qb, kv_block=kb)

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, kb, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, kb, 1, hd),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),      # running max m
            pltpu.VMEM((qb, 1), jnp.float32),      # running denom l
            pltpu.VMEM((qb, hd), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
