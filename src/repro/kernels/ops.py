"""Public kernel entry points with backend dispatch.

Every op takes `impl`:
  * "pallas"    — the Pallas TPU kernel (compiled; TPU only)
  * "interpret" — the Pallas kernel in interpret mode (CPU correctness)
  * "xla"       — the pure-XLA chunked/blockwise form (fast everywhere,
                  what the dry-run lowers so cost_analysis stays
                  meaningful on the CPU backend)
  * "ref"       — the materialize-everything oracle (tests only)
  * "auto"      — pallas on TPU, xla elsewhere
"""
from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fleet_drift import fleet_drift as _fdrift_pallas
from repro.kernels.fleet_drift import fleet_drift_xla as _fdrift_xla
from repro.kernels.mlstm_scan import mlstm_scan as _mlstm_pallas
from repro.kernels.pairwise_js import pairwise_js as _pjs_pallas
from repro.kernels.pairwise_js import pairwise_js_xla as _pjs_xla
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_block: int = 128, kv_block: int = 128, impl: str = "auto"):
    """Flash attention. q: (B,S,H,hd); k,v: (B,T,K,hd), H % K == 0."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    if impl in ("pallas", "interpret"):
        return _flash(q, k, v, causal=causal, window=window,
                      q_block=q_block, kv_block=kv_block,
                      interpret=(impl == "interpret"))
    # xla: blockwise exact attention (see models.layers.attention_full's
    # scan form); the oracle is cheap enough at test shapes, so reuse it
    # under jit for the xla path
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


def pairwise_js(p, q, *, eps: float = 1e-12, impl: str = "auto"):
    """(N, M) Jensen-Shannon divergence matrix. p: (N, B); q: (M, B).

    The drift-signature similarity engine for fleet-scale grouping:
    one call scores every request histogram against every candidate
    stream signature (core.signature_index.SignatureIndex).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pairwise_js_ref(p, q, eps=eps)
    if impl in ("pallas", "interpret"):
        return _pjs_pallas(p, q, eps=eps, interpret=(impl == "interpret"))
    return _pjs_xla(p, q, eps=eps)


def fleet_drift(tokens, ref, *, buckets: int, vocab: int = 0,
                eps: float = 1e-12, impl: str = "auto"):
    """Fused fleet drift scoring. tokens: (N, T) int; ref: (N, buckets).

    One call histograms every stream's live window and scores it with
    Jensen-Shannon divergence against that stream's reference — the
    batched replacement for the controller's per-stream
    token_histogram + js_divergence loop (core.drift.FleetDriftDetector).
    Returns (scores (N,) fp32, live hists (N, buckets) fp32).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.fleet_drift_ref(tokens, ref, buckets=buckets,
                                    vocab=vocab, eps=eps)
    if impl in ("pallas", "interpret"):
        return _fdrift_pallas(tokens, ref, buckets=buckets, vocab=vocab,
                              eps=eps, interpret=(impl == "interpret"))
    return _fdrift_xla(tokens, ref, buckets=buckets, vocab=vocab, eps=eps)


def mlstm(q, k, v, igate, fgate, *, chunk: int = 128, impl: str = "auto"):
    """Chunkwise mLSTM. q,k,v: (B,S,H,P); gates: (B,S,H)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.mlstm_recurrent(q, k, v, igate, fgate)
    if impl in ("pallas", "interpret"):
        return _mlstm_pallas(q, k, v, igate, fgate, chunk=chunk,
                             interpret=(impl == "interpret"))
    from repro.models.xlstm import mlstm_chunked
    return mlstm_chunked(q, k, v, igate, fgate, chunk=chunk)


def ssd(x, dt, A, Bm, Cm, D, *, chunk: int = 128, impl: str = "auto"):
    """Chunkwise SSD. x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm,Cm: (B,S,N)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_recurrent(x, dt, A, Bm, Cm, D)
    if impl in ("pallas", "interpret"):
        return _ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=(impl == "interpret"))
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
