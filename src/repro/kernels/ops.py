"""Public kernel entry points with backend dispatch.

Every op takes `impl`:
  * "pallas"    — the Pallas TPU kernel (compiled; TPU only)
  * "interpret" — the Pallas kernel in interpret mode (CPU correctness)
  * "xla"       — the pure-XLA chunked/blockwise form (fast everywhere,
                  what the dry-run lowers so cost_analysis stays
                  meaningful on the CPU backend)
  * "ref"       — the materialize-everything oracle (tests only)
  * "auto"      — pallas on TPU, xla elsewhere

The fleet row-axis ops (`pairwise_js`, `fleet_drift`) additionally take
`mesh`: a 1-D (or leading-axis) device mesh. With a mesh the row axis
is padded to a device multiple and the SAME per-shard kernel runs under
`shard_map`, one contiguous row block per device — every row's math is
device-local and unchanged, so sharded scores are bit-identical to the
single-device call (the PR 2–5 bit-identity bar; parity-tested on a
forced 8-device host mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as _P

from repro.kernels import ref as _ref
from repro.kernels._compat import shard_map as _shard_map
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fleet_drift import fleet_drift as _fdrift_pallas
from repro.kernels.fleet_drift import fleet_drift_xla as _fdrift_xla
from repro.kernels.mlstm_scan import mlstm_scan as _mlstm_pallas
from repro.kernels.pairwise_js import pairwise_js as _pjs_pallas
from repro.kernels.pairwise_js import pairwise_js_xla as _pjs_xla
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_block: int = 128, kv_block: int = 128, impl: str = "auto"):
    """Flash attention. q: (B,S,H,hd); k,v: (B,T,K,hd), H % K == 0."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    if impl in ("pallas", "interpret"):
        return _flash(q, k, v, causal=causal, window=window,
                      q_block=q_block, kv_block=kv_block,
                      interpret=(impl == "interpret"))
    # xla: blockwise exact attention (see models.layers.attention_full's
    # scan form); the oracle is cheap enough at test shapes, so reuse it
    # under jit for the xla path
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


def _row_shards(mesh) -> int:
    """Device count of a fleet mesh; 0 when no mesh / nothing to shard."""
    if mesh is None:
        return 0
    n = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    return n if n > 1 else 0


def _pad_rows(x, n_pad):
    """Pad the leading (row) axis with zero rows (padding rows are
    sliced off after the sharded call — their values never matter)."""
    if n_pad == 0:
        return x
    x = jnp.asarray(x)
    return jnp.concatenate(
        [x, jnp.zeros((n_pad,) + x.shape[1:], x.dtype)], axis=0)


def pairwise_js(p, q, *, eps: float = 1e-12, impl: str = "auto",
                mesh=None, shard: str = "rows"):
    """(N, M) Jensen-Shannon divergence matrix. p: (N, B); q: (M, B).

    The drift-signature similarity engine for fleet-scale grouping:
    one call scores every request histogram against every candidate
    stream signature (core.signature_index.SignatureIndex).

    With `mesh`, one side is block-sharded across devices and the other
    replicated — shard="rows" splits p (each device computes an
    (N/D, M) stripe), shard="cols" splits q (an (N, M/D) stripe; what
    the signature index uses, since its fleet axis is q). Each stripe
    runs the same kernel on device-local rows, so the assembled matrix
    is bit-identical to single-device.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.pairwise_js_ref(p, q, eps=eps)

    def _local(pp, qq):
        if impl in ("pallas", "interpret"):
            return _pjs_pallas(pp, qq, eps=eps,
                               interpret=(impl == "interpret"))
        return _pjs_xla(pp, qq, eps=eps)

    shards = _row_shards(mesh)
    if shards:
        ax = mesh.axis_names[0]
        if shard == "cols":
            m = np.shape(q)[0]
            pad = (-m) % shards
            f = _shard_map(_local, mesh=mesh,
                           in_specs=(_P(), _P(ax)),
                           out_specs=_P(None, ax))
            out = f(jnp.asarray(p), _pad_rows(q, pad))
            return out[:, :m]
        n = np.shape(p)[0]
        pad = (-n) % shards
        f = _shard_map(_local, mesh=mesh,
                       in_specs=(_P(ax), _P()), out_specs=_P(ax))
        out = f(_pad_rows(p, pad), jnp.asarray(q))
        return out[:n]
    return _local(p, q)


def fleet_drift(tokens, ref, *, buckets: int, vocab: int = 0,
                eps: float = 1e-12, impl: str = "auto", mesh=None):
    """Fused fleet drift scoring. tokens: (N, T) int; ref: (N, buckets).

    One call histograms every stream's live window and scores it with
    Jensen-Shannon divergence against that stream's reference — the
    batched replacement for the controller's per-stream
    token_histogram + js_divergence loop (core.drift.FleetDriftDetector).
    Returns (scores (N,) fp32, live hists (N, buckets) fp32).

    With `mesh`, the stream rows are block-sharded: each device scores
    its own contiguous row block with the same kernel (histogram + JS
    are row-local, no collectives), bit-identical to single-device.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.fleet_drift_ref(tokens, ref, buckets=buckets,
                                    vocab=vocab, eps=eps)

    def _local(tok, r):
        if impl in ("pallas", "interpret"):
            return _fdrift_pallas(tok, r, buckets=buckets, vocab=vocab,
                                  eps=eps, interpret=(impl == "interpret"))
        return _fdrift_xla(tok, r, buckets=buckets, vocab=vocab, eps=eps)

    shards = _row_shards(mesh)
    if shards:
        n = np.shape(tokens)[0]
        pad = (-n) % shards
        ax = mesh.axis_names[0]
        f = _shard_map(_local, mesh=mesh,
                       in_specs=(_P(ax), _P(ax)),
                       out_specs=(_P(ax), _P(ax)))
        scores, hists = f(_pad_rows(tokens, pad), _pad_rows(ref, pad))
        return scores[:n], hists[:n]
    return _local(tokens, ref)


def mlstm(q, k, v, igate, fgate, *, chunk: int = 128, impl: str = "auto"):
    """Chunkwise mLSTM. q,k,v: (B,S,H,P); gates: (B,S,H)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.mlstm_recurrent(q, k, v, igate, fgate)
    if impl in ("pallas", "interpret"):
        return _mlstm_pallas(q, k, v, igate, fgate, chunk=chunk,
                             interpret=(impl == "interpret"))
    from repro.models.xlstm import mlstm_chunked
    return mlstm_chunked(q, k, v, igate, fgate, chunk=chunk)


def ssd(x, dt, A, Bm, Cm, D, *, chunk: int = 128, impl: str = "auto"):
    """Chunkwise SSD. x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm,Cm: (B,S,N)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_recurrent(x, dt, A, Bm, Cm, D)
    if impl in ("pallas", "interpret"):
        return _ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                           interpret=(impl == "interpret"))
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
