"""Mamba-2-style SSD chunk scan as a Pallas TPU kernel (hymba SSM heads).

Design:
  * Grid (B, H, n_chunks): chunk dim sequential ("arbitrary"), carrying
    the (P x N) per-head SSM state in VMEM scratch; batch/head parallel.
  * Tiles: x (1, Q, 1, P); B/C (1, Q, N) shared across heads (single
    group, as in hymba); dt (1, Q, 1); A and D enter as (1,)-blocks of
    per-head scalars. Intra-chunk work is the (Q x Q) masked decay matmul
    — MXU-shaped at Q=128.
  * Everything in fp32; the decay is computed in log space
    (cumsum of dt * A) and exponentiated once per term.

Validated in interpret mode against ref.ssd_recurrent and the XLA
chunked form (models.ssm.ssd_chunked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref,
                st_ref, *, chunk: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    Q = chunk
    x = x_ref[0, :, 0, :].astype(F32)                      # (Q, P)
    dt = dt_ref[0, :, 0].astype(F32)                       # (Q,)
    A = a_ref[0].astype(F32)                               # scalar
    Bm = b_ref[0].astype(F32)                              # (Q, N)
    Cm = c_ref[0].astype(F32)                              # (Q, N)
    D = d_ref[0].astype(F32)                               # scalar

    pos = ci * Q + jax.lax.iota(jnp.int32, Q)
    dt = jnp.where(pos < seq_len, dt, 0.0)                 # pad: no-op steps

    dA = dt * A                                            # (Q,) log decay
    cum = jnp.cumsum(dA)
    seg_end = cum[-1]

    # ---- intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    li = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(li), 0.0) * dt[None, :]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)   # (Q, Q)
    W = CB * Lmat
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)    # (Q, P)

    # ---- inter-chunk: y_i += exp(cum_i) * C_i . state_prev (N,P) ----
    st_prev = st_ref[...]                                  # (N, P)
    y = y + jax.lax.dot_general(Cm, st_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=F32) \
        * jnp.exp(cum)[:, None]

    o_ref[0, :, 0, :] = (y + x * D).astype(o_ref.dtype)

    # ---- state update: st[n,p] = exp(seg_end) st + sum_j w_j B[j,n] x[j,p]
    wj = jnp.exp(seg_end - cum) * dt                       # (Q,)
    st_ref[...] = jnp.exp(seg_end) * st_prev + jax.lax.dot_general(
        Bm * wj[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=F32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H) post-softplus; A, D: (H,);
    Bm, Cm: (B, S, N). Returns y (B, S, H, P) in x.dtype."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q, seq_len=S)
    x_spec = pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0))
    dt_spec = pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h))
    bc_spec = pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0))
    sc_spec = pl.BlockSpec((1,), lambda b, h, c: (h,))
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[x_spec, dt_spec, sc_spec, bc_spec, bc_spec, sc_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), F32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
    return out[:, :S]
