"""Pallas / sharding API compatibility across jax versions.

jax renamed the TPU compiler-params dataclass: 0.4.x exposes
`pltpu.TPUCompilerParams`, newer releases `pltpu.CompilerParams`.
Every kernel imports the resolved name from here.

Likewise `shard_map`: 0.4.x ships it under
`jax.experimental.shard_map` (keyword `check_rep`), newer releases as
`jax.shard_map` (keyword `check_vma`). `shard_map` below resolves the
callable and hides the keyword rename; replication checking is
disabled either way because Pallas calls inside the mapped function
have no replication rule on older jax.
"""
from __future__ import annotations

import jax as _jax
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")

_shard_map = getattr(_jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs):
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
