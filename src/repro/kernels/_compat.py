"""Pallas API compatibility across jax versions.

jax renamed the TPU compiler-params dataclass: 0.4.x exposes
`pltpu.TPUCompilerParams`, newer releases `pltpu.CompilerParams`.
Every kernel imports the resolved name from here.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
