"""Pure-jnp oracles for every Pallas kernel.

These are the *definitions of correctness*: deliberately simple,
materialize-everything implementations that the kernel sweep tests
(tests/test_kernels.py) compare against with assert_allclose over shape /
dtype grids. They are also the CPU fallback path of repro.kernels.ops.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30
F32 = jnp.float32


# ---------------------------------------------------------------------------
# Attention oracle
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Materialized softmax attention with GQA.

    q: (B, S, H, hd); k, v: (B, T, K, hd) with H % K == 0.
    window > 0 limits key visibility to  0 <= i - j < window  (causal
    sliding window). Returns (B, S, H, hd) in q.dtype.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(F32), kk.astype(F32))
    s = s / math.sqrt(hd)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        # queries are the last S positions of the T-long key space
        qpos = i + (T - S)
        mask &= j <= qpos
        if window > 0:
            mask &= (qpos - j) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, vv.astype(F32))
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Pairwise Jensen-Shannon divergence oracle
# ---------------------------------------------------------------------------
def pairwise_js_ref(p, q, *, eps: float = 1e-12):
    """Materialized (N, M, B) JS-divergence matrix between histogram rows.

    p: (N, B); q: (M, B), nonnegative (rows need not be normalized —
    eps-shift + renormalize matches core.drift.js_divergence). Returns
    (N, M) fp32 with out[i, j] = JS(p[i], q[j]).
    """
    p = jnp.asarray(p, F32) + eps
    q = jnp.asarray(q, F32) + eps
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    pe = p[:, None, :]                                   # (N, 1, B)
    qe = q[None, :, :]                                   # (1, M, B)
    m = 0.5 * (pe + qe)                                  # (N, M, B)
    kl_pm = jnp.sum(pe * jnp.log(pe / m), axis=-1)
    kl_qm = jnp.sum(qe * jnp.log(qe / m), axis=-1)
    return 0.5 * (kl_pm + kl_qm)


# ---------------------------------------------------------------------------
# Fleet drift (fused histogram + rowwise JS) oracle
# ---------------------------------------------------------------------------
def fleet_drift_ref(tokens, ref, *, buckets: int, vocab: int = 0,
                    eps: float = 1e-12):
    """Materialized fused drift scoring.

    tokens: (N, T) int; ref: (N, buckets) nonneg reference histograms.
    Per stream i: histogram tokens[i] over `buckets` (clip rule of
    drift.token_histogram when vocab > 0, modulo hashing otherwise),
    normalize, and score JS(hist_i, ref_i) with the eps-shift +
    renormalize of drift.js_divergence. Returns (scores (N,) fp32,
    hists (N, buckets) fp32).
    """
    t = jnp.asarray(tokens, jnp.int32)
    N, _ = t.shape
    if N == 0:
        return jnp.zeros((0,), F32), jnp.zeros((0, buckets), F32)
    if vocab:
        idx = jnp.clip((t * buckets) // vocab, 0, buckets - 1)
    else:
        idx = t % buckets
    onehot = jax.nn.one_hot(idx, buckets, dtype=F32)     # (N, T, B)
    h = jnp.sum(onehot, axis=1)
    s = jnp.sum(h, axis=-1, keepdims=True)
    h = h / jnp.maximum(s, 1.0)
    p = h.astype(F32) + eps
    q = jnp.asarray(ref, F32) + eps
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    m = 0.5 * (p + q)
    kl_pm = jnp.sum(p * jnp.log(p / m), axis=-1)
    kl_qm = jnp.sum(q * jnp.log(q / m), axis=-1)
    return 0.5 * (kl_pm + kl_qm), h


# ---------------------------------------------------------------------------
# mLSTM oracle — strictly sequential recurrence (arXiv:2405.04517 eq. 19-27)
# ---------------------------------------------------------------------------
def mlstm_recurrent(q, k, v, igate, fgate, *, init_state=None,
                    return_state: bool = False):
    """Token-by-token stabilized mLSTM.

    q, k, v: (B, S, H, P); igate, fgate: (B, S, H) raw preactivations.
    Returns h (B, S, H, P) [, (C, n, m) final state].
    """
    B, S, H, P = q.shape
    scale = 1.0 / math.sqrt(P)
    if init_state is None:
        C = jnp.zeros((B, H, P, P), F32)
        n = jnp.zeros((B, H, P), F32)
        m = jnp.full((B, H), -jnp.inf, F32)
    else:
        C, n, m = (s.astype(F32) for s in init_state)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        lf = jax.nn.log_sigmoid(ft.astype(F32))
        it = it.astype(F32)
        m_new = jnp.maximum(lf + m, it)
        w_old = jnp.exp(lf + m - m_new)
        w_in = jnp.exp(it - m_new)
        C = w_old[..., None, None] * C + w_in[..., None, None] * \
            jnp.einsum("bhp,bhr->bhpr", vt.astype(F32), kt.astype(F32))
        n = w_old[..., None] * n + w_in[..., None] * kt.astype(F32)
        qf = qt.astype(F32) * scale
        num = jnp.einsum("bhpr,bhr->bhp", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), igate.transpose(1, 0, 2),
          fgate.transpose(1, 0, 2))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    h = hs.transpose(1, 0, 2, 3).astype(q.dtype)
    if return_state:
        return h, (C, n, m)
    return h


# ---------------------------------------------------------------------------
# SSD (Mamba-2) oracle — sequential selective state-space recurrence
# ---------------------------------------------------------------------------
def ssd_recurrent(x, dt, A, Bm, Cm, D, *, init_state=None,
                  return_state: bool = False):
    """Token-by-token SSD.

    x: (B, S, H, P); dt: (B, S, H) post-softplus; A: (H,) negative;
    Bm, Cm: (B, S, N); D: (H,). Returns y (B, S, H, P) [, state (B,H,P,N)].
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if init_state is None:
        st = jnp.zeros((B, H, P, N), F32)
    else:
        st = init_state.astype(F32)

    def step(st, xs):
        xt, dtt, bt, ct = xs
        dA = dtt.astype(F32) * A.astype(F32)[None, :]           # (B,H)
        st = jnp.exp(dA)[:, :, None, None] * st + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt.astype(F32), bt.astype(F32),
            xt.astype(F32))
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(F32), st)
        y = y + xt.astype(F32) * D.astype(F32)[None, :, None]
        return st, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    st, ys = jax.lax.scan(step, st, xs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    if return_state:
        return y, st
    return y
