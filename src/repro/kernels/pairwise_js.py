"""Batched pairwise Jensen-Shannon divergence as a Pallas TPU kernel.

The drift-signature similarity engine behind dynamic grouping (Alg. 2):
given N live-stream histograms and M reference histograms it produces
the full (N, M) JS-divergence matrix in one shot, replacing the
per-pair Python `drift.js_divergence` loop for fleet-scale candidate
selection (SignatureIndex in core/signature_index.py).

Design:
  * Grid (nN, nM), both parallel; each cell owns a (TN, TM) output
    tile. p rows tile over the first grid dim, q rows over the second.
  * Per tile: rows are eps-shifted and renormalized (matching
    drift.js_divergence), per-row negentropies hp/hq are computed once,
    and the cross term sum_b m*log m over the (TN, TM, B) broadcast of
    m = (p+q)/2 finishes JS = 0.5*(hp + hq) - sum m log m. All fp32.
  * N and M are zero-padded to tile multiples; padded rows normalize to
    the eps-uniform histogram (finite everywhere) and are sliced away.

`pairwise_js_xla` is the chunked pure-jnp twin (lax.map over q blocks,
bounding the broadcast at (N, block, B)) used on non-TPU backends.
Validated in interpret mode against ref.pairwise_js_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _normalize(x, eps: float):
    x = x.astype(F32) + eps
    return x / jnp.sum(x, axis=-1, keepdims=True)


def _pjs_kernel(p_ref, q_ref, o_ref, *, eps: float):
    p = _normalize(p_ref[...], eps)                     # (TN, B)
    q = _normalize(q_ref[...], eps)                     # (TM, B)
    hp = jnp.sum(p * jnp.log(p), axis=-1)               # (TN,)
    hq = jnp.sum(q * jnp.log(q), axis=-1)               # (TM,)
    m = 0.5 * (p[:, None, :] + q[None, :, :])           # (TN, TM, B)
    cross = jnp.sum(m * jnp.log(m), axis=-1)            # (TN, TM)
    o_ref[...] = 0.5 * (hp[:, None] + hq[None, :]) - cross


@functools.partial(jax.jit,
                   static_argnames=("eps", "n_block", "m_block", "interpret"))
def pairwise_js(p, q, *, eps: float = 1e-12, n_block: int = 64,
                m_block: int = 64, interpret: bool = False):
    """p: (N, B) and q: (M, B) nonneg histograms -> (N, M) fp32 JS."""
    N, B = p.shape
    M = q.shape[0]
    if N == 0 or M == 0:
        return jnp.zeros((N, M), F32)
    TN = min(n_block, max(8, N))
    TM = min(m_block, max(8, M))
    pn, pm = (-N) % TN, (-M) % TM
    if pn:
        p = jnp.pad(p, ((0, pn), (0, 0)))
    if pm:
        q = jnp.pad(q, ((0, pm), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_pjs_kernel, eps=eps),
        grid=((N + pn) // TN, (M + pm) // TM),
        in_specs=[pl.BlockSpec((TN, B), lambda i, j: (i, 0)),
                  pl.BlockSpec((TM, B), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((TN, TM), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N + pn, M + pm), F32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(p, q)
    return out[:N, :M]


@functools.partial(jax.jit, static_argnames=("eps", "block"))
def pairwise_js_xla(p, q, *, eps: float = 1e-12, block: int = 512):
    """Chunked pure-jnp form: identical math, (N, block, B) peak memory."""
    N, B = p.shape
    M = q.shape[0]
    if N == 0 or M == 0:
        return jnp.zeros((N, M), F32)
    p = _normalize(p, eps)
    q = _normalize(q, eps)
    hp = jnp.sum(p * jnp.log(p), axis=-1)
    hq = jnp.sum(q * jnp.log(q), axis=-1)
    TM = min(block, M)
    pad = (-M) % TM
    if pad:                      # pad rows are eps-uniform -> finite logs
        q = jnp.pad(q, ((0, pad), (0, 0)), constant_values=1.0 / B)
        hq = jnp.pad(hq, (0, pad))
    qb = q.reshape(-1, TM, B)
    hqb = hq.reshape(-1, TM)

    def one(args):
        qi, hqi = args
        m = 0.5 * (p[:, None, :] + qi[None, :, :])
        cross = jnp.sum(m * jnp.log(m), axis=-1)
        return 0.5 * (hp[:, None] + hqi[None, :]) - cross

    out = jax.lax.map(one, (qb, hqb))                   # (nb, N, TM)
    return jnp.moveaxis(out, 0, 1).reshape(N, -1)[:, :M]
