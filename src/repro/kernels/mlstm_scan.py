"""Chunkwise-parallel mLSTM (xLSTM matrix memory) as a Pallas TPU kernel.

Design:
  * Grid (B, H, n_chunks) — the chunk dimension is sequential
    ("arbitrary"), carrying the (C, n, m) recurrent state in VMEM/SMEM
    scratch; batch and head dims are parallel.
  * Per-invocation tiles: q/k/v (1, Q, 1, P) with Q=chunk (default 128)
    and P=head dim — the (Q x Q) intra-chunk weight matrix and the
    (P x P) matrix memory both fit VMEM and are MXU-shaped.
  * All gate math is fp32 with the paper's log-max stabilization:
      m_t = max(logsig(f) + m_{t-1}, i_t)  carried in log space.

Validated in interpret mode against ref.mlstm_recurrent (the sequential
oracle) and the XLA chunked form (models.xlstm.mlstm_chunked).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _mlstm_kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, o_ref,
                  C_ref, n_ref, m_ref, *, chunk: int, head_dim: int,
                  seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)

    Q, P = chunk, head_dim
    scale = 1.0 / math.sqrt(P)
    q = q_ref[0, :, 0, :].astype(F32) * scale              # (Q, P)
    k = k_ref[0, :, 0, :].astype(F32)
    v = v_ref[0, :, 0, :].astype(F32)
    ig = ig_ref[0, :, 0].astype(F32)                       # (Q,)
    fg = fg_ref[0, :, 0].astype(F32)

    # mask tokens beyond the true sequence end (zero-padded chunks)
    pos = ci * Q + jax.lax.iota(jnp.int32, Q)
    valid = pos < seq_len
    ig = jnp.where(valid, ig, -1e30)                       # never written
    lf = jnp.where(valid, jax.nn.log_sigmoid(fg), 0.0)     # no decay

    b = jnp.cumsum(lf)                                     # (Q,) inclusive
    b_last = b[-1]

    C_prev = C_ref[...]                                    # (P, P)
    n_prev = n_ref[...]                                    # (1, P)
    m_prev = m_ref[0, 0]

    # ---- intra-chunk log weights: d[i,j] = b_i - b_j + i_j (i >= j) ----
    d = b[:, None] - b[None, :] + ig[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    d = jnp.where(ii >= jj, d, -jnp.inf)
    d_inter = b + m_prev                                   # (Q,)
    m_loc = jnp.maximum(jnp.max(d, axis=1), d_inter)
    m_loc = jnp.maximum(m_loc, -1e30)

    w_intra = jnp.exp(d - m_loc[:, None])                  # (Q, Q)
    w_inter = jnp.exp(d_inter - m_loc)                     # (Q,)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)   # (Q, Q)
    wqk = qk * w_intra
    h_intra = jax.lax.dot_general(wqk, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=F32)
    # h_inter[i, p] = w_inter[i] * sum_r q[i, r] C[p, r]
    h_inter = jax.lax.dot_general(q, C_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=F32)
    h_num = h_intra + h_inter * w_inter[:, None]

    nq = jnp.sum(wqk, axis=1) + \
        jnp.sum(q * n_prev, axis=1) * w_inter              # (Q,)
    denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_loc))
    o_ref[0, :, 0, :] = (h_num / denom[:, None]).astype(o_ref.dtype)

    # ---- state update toward chunk end ----
    a = ig + (b_last - b)                                  # (Q,)
    m_new = jnp.maximum(b_last + m_prev, jnp.max(a))
    w_old = jnp.exp(b_last + m_prev - m_new)
    w_in = jnp.exp(a - m_new)                              # (Q,)
    # C_new[p, r] = w_old * C[p, r] + sum_j w_in[j] v[j, p] k[j, r]
    C_ref[...] = w_old * C_prev + jax.lax.dot_general(
        v * w_in[:, None], k, (((0,), (0,)), ((), ())),
        preferred_element_type=F32)
    n_ref[...] = w_old * n_prev + jnp.sum(k * w_in[:, None], axis=0,
                                          keepdims=True)
    m_ref[0, 0] = m_new


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, igate, fgate, *, chunk: int = 128,
               interpret: bool = False):
    """q, k, v: (B, S, H, P); igate, fgate: (B, S, H) raw preactivations.
    Returns h (B, S, H, P) in q.dtype."""
    B, S, H, P = q.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        z = jnp.zeros((B, pad, H, P), q.dtype)
        q = jnp.concatenate([q, z], 1)
        k = jnp.concatenate([k, z], 1)
        v = jnp.concatenate([v, z], 1)
        zg = jnp.zeros((B, pad, H), igate.dtype)
        igate = jnp.concatenate([igate, zg], 1)
        fgate = jnp.concatenate([fgate, zg], 1)
    Sp = S + pad
    nc = Sp // Q

    kernel = functools.partial(_mlstm_kernel, chunk=Q, head_dim=P,
                               seq_len=S)
    qkv_spec = pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0))
    gate_spec = pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h))
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((P, P), F32),       # matrix memory C
            pltpu.VMEM((1, P), F32),       # normalizer n
            pltpu.SMEM((1, 1), F32),       # log-max m
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, igate, fgate)
    return out[:, :S]
