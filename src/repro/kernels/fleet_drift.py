"""Fused fleet drift detection as a Pallas TPU kernel.

The hot loop of ECCO's window step 1: every stream's live window of
tokens becomes a bucket histogram, and that histogram is scored with
Jensen-Shannon divergence against the stream's own reference histogram
(core.drift.DriftDetector does this one stream at a time in Python).
This kernel fuses both stages for the whole fleet in one call:

    tokens (N, T) int32, ref (N, B)  ->  scores (N,), live hists (N, B)

Design:
  * Grid (nN,), parallel; each cell owns a (TN, T) token tile and the
    matching (TN, B) reference tile.
  * Histogram: bucket indices via the same clip/modulo rule as
    drift.token_histogram, then a one-hot compare against a
    broadcasted_iota over buckets summed across T (Pallas has no
    scatter-add; the (TN, T, B) broadcast stays comfortably in VMEM at
    drift shapes: T ~ hundreds, B ~ 64).
  * JS: live rows normalized to probabilities, then the eps-shift +
    renormalize + 0.5*(KL(p||m) + KL(q||m)) sequence of
    drift.js_divergence, rowwise against the reference tile. All fp32.
  * N is zero-padded to a tile multiple; padded token rows histogram to
    a delta at bucket 0 and padded ref rows normalize to eps-uniform —
    finite everywhere — and are sliced away.

`fleet_drift_xla` is the chunked pure-jnp twin (lax.map over stream
blocks, scatter-add histogramming) used on non-TPU backends. Validated
in interpret mode against ref.fleet_drift_ref; exactness-critical
consumers (FleetDriftDetector's trigger decisions) combine these fp32
scores with a float64 near-threshold rescore — see core/drift.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

F32 = jnp.float32


def _bucket_idx(toks, buckets: int, vocab: int):
    """Bucket index per token — same rule as drift.token_histogram:
    clip((t * buckets) // vocab) when a vocab is known (tokens at
    exactly `vocab` land in the top bucket, not bucket `buckets`),
    modulo hashing otherwise (vocab == 0)."""
    if vocab:
        return jnp.clip((toks * buckets) // vocab, 0, buckets - 1)
    return toks % buckets


def _normalize(x, eps: float):
    x = x.astype(F32) + eps
    return x / jnp.sum(x, axis=-1, keepdims=True)


def _rowwise_js(h, ref, eps: float):
    p = _normalize(h, eps)
    q = _normalize(ref, eps)
    m = 0.5 * (p + q)
    kl_pm = jnp.sum(p * jnp.log(p / m), axis=-1)
    kl_qm = jnp.sum(q * jnp.log(q / m), axis=-1)
    return 0.5 * (kl_pm + kl_qm)


def _fleet_drift_kernel(tok_ref, ref_ref, score_ref, hist_ref, *,
                        buckets: int, vocab: int, eps: float):
    toks = tok_ref[...]                                  # (TN, T) int32
    idx = _bucket_idx(toks, buckets, vocab)
    b = jax.lax.broadcasted_iota(jnp.int32,
                                 (*idx.shape, buckets), 2)
    h = jnp.sum((idx[:, :, None] == b).astype(F32), axis=1)   # (TN, B)
    s = jnp.sum(h, axis=-1, keepdims=True)               # == T per row
    h = h / jnp.maximum(s, 1.0)
    hist_ref[...] = h
    score_ref[...] = _rowwise_js(h, ref_ref[...], eps)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("buckets", "vocab", "eps", "n_block",
                                    "interpret"))
def fleet_drift(tokens, ref, *, buckets: int, vocab: int = 0,
                eps: float = 1e-12, n_block: int = 128,
                interpret: bool = False):
    """tokens: (N, T) int; ref: (N, buckets) nonneg histograms.
    Returns (scores (N,) fp32, live hists (N, buckets) fp32)."""
    N, T = tokens.shape
    if N == 0:
        return jnp.zeros((0,), F32), jnp.zeros((0, buckets), F32)
    tokens = tokens.astype(jnp.int32)
    ref = ref.astype(F32)
    TN = min(n_block, max(8, N))
    pn = (-N) % TN
    if pn:
        tokens = jnp.pad(tokens, ((0, pn), (0, 0)))
        ref = jnp.pad(ref, ((0, pn), (0, 0)))

    scores, hists = pl.pallas_call(
        functools.partial(_fleet_drift_kernel, buckets=buckets,
                          vocab=vocab, eps=eps),
        grid=((N + pn) // TN,),
        in_specs=[pl.BlockSpec((TN, T), lambda i: (i, 0)),
                  pl.BlockSpec((TN, buckets), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TN, 1), lambda i: (i, 0)),
                   pl.BlockSpec((TN, buckets), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N + pn, 1), F32),
                   jax.ShapeDtypeStruct((N + pn, buckets), F32)],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tokens, ref)
    return scores[:N, 0], hists[:N]


@functools.partial(jax.jit,
                   static_argnames=("buckets", "vocab", "eps", "block"))
def fleet_drift_xla(tokens, ref, *, buckets: int, vocab: int = 0,
                    eps: float = 1e-12, block: int = 1024):
    """Chunked pure-jnp form: scatter-add histograms per stream block,
    identical math, (block, buckets) peak memory per step."""
    N, T = tokens.shape
    if N == 0:
        return jnp.zeros((0,), F32), jnp.zeros((0, buckets), F32)
    tokens = tokens.astype(jnp.int32)
    ref = ref.astype(F32)
    TB = min(block, N)
    pad = (-N) % TB
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
        ref = jnp.pad(ref, ((0, pad), (0, 0)), constant_values=1.0)
    tb = tokens.reshape(-1, TB, T)
    rb = ref.reshape(-1, TB, buckets)

    def one(args):
        toks, r = args
        idx = _bucket_idx(toks, buckets, vocab)
        flat = (idx + buckets * jnp.arange(TB, dtype=jnp.int32)[:, None])
        h = jnp.zeros((TB * buckets,), F32).at[flat.reshape(-1)].add(1.0)
        h = h.reshape(TB, buckets)
        s = jnp.sum(h, axis=-1, keepdims=True)
        h = h / jnp.maximum(s, 1.0)
        return _rowwise_js(h, r, eps), h

    scores, hists = jax.lax.map(one, (tb, rb))
    return scores.reshape(-1)[:N], hists.reshape(-1, buckets)[:N]
